"""Transformer substrate: GQA attention (flash-chunked), MLPs, MoE.

Attention uses a two-level chunked online-softmax (pure-JAX flash) so the
[S, S] score matrix never materializes — required to fit 16 GB/chip at 32k
sequence length.  MoE ships two dispatch implementations:

  * ``dense``: sort/scatter dispatch under plain pjit — the baseline; SPMD
    inserts the collectives (observed as all-gathers in the dry-run HLO);
  * ``a2a``: shard_map expert-parallel dispatch with explicit all_to_all —
    the beyond-paper optimization evaluated in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import LEGACY_JAX, axis_size, get_abstract_mesh

from .common import ACTIVATIONS, apply_rope, dense_init, rms_norm, split_keys
from .config import ModelConfig
from .sharding import div_or_none, dp, shard, tp


# =============================================================================
# bf16-wire row-parallel matmul (§Perf hillclimb B)
# =============================================================================

def row_parallel_matmul(h: jnp.ndarray, w: jnp.ndarray, cfg: ModelConfig):
    """y[B,S,d] = h[B,S,n] @ w[n,d] with n TP-sharded.

    With ``cfg.bf16_reduce`` the cross-chip partial-sum reduction happens on
    bf16 values (per-shard accumulation stays f32 inside the dot): XLA's
    default plan all-reduces the pre-downcast f32 accumulators, doubling the
    wire bytes of every row-parallel matmul — measured as 96/101 GiB of the
    collective traffic on the codeqwen train_4k cell (EXPERIMENTS.md §Perf)."""
    if not cfg.bf16_reduce or tp() is None:
        return jnp.einsum("bsn,nd->bsd", h, w)
    from repro.compat import shard_map

    mesh = get_abstract_mesh()
    if mesh is None or mesh.empty or tp() not in mesh.axis_names:
        return jnp.einsum("bsn,nd->bsd", h, w)
    tp_axis = tp()
    dp_spec = dp()

    def local(hl, wl):
        part = jnp.einsum("bsn,nd->bsd", hl, wl,
                          preferred_element_type=jnp.float32)
        return jax.lax.psum(part.astype(jnp.bfloat16), tp_axis)

    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(dp_spec, None, tp_axis), P(tp_axis, None)),
                   out_specs=P(dp_spec, None, None))

    # custom VJP: the backward needs NO collective — dy is replicated over tp,
    # so dh = dy @ w^T is tp-sharded locally and dw = h^T dy is shard-local.
    # (shard_map's conservative transpose would insert a second f32 psum of
    # the cotangent, which *regressed* the collective term; see §Perf B2.)
    @jax.custom_vjp
    def rp(hh, ww):
        return fn(hh, ww).astype(hh.dtype)

    def rp_fwd(hh, ww):
        return rp(hh, ww), (hh, ww)

    def rp_bwd(res, dy):
        hh, ww = res
        dh = jnp.einsum("bsd,nd->bsn", dy, ww).astype(hh.dtype)
        dw = jnp.einsum("bsn,bsd->nd", hh, dy,
                        preferred_element_type=jnp.float32).astype(ww.dtype)
        return dh, dw

    rp.defvjp(rp_fwd, rp_bwd)
    return rp(h, w)


# =============================================================================
# int8 KV cache (§Perf hillclimb C)
# =============================================================================

def kv_quantize(x: jnp.ndarray):
    """Per-(token, head) symmetric int8: x [B,S,K,hd] -> (int8, f32 scale)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                    keepdims=True) / 127.0 + 1e-8
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


# =============================================================================
# Attention
# =============================================================================

def init_attention(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = split_keys(key, 4)
    return {
        "wq": dense_init(ks[0], (d, H * hd), dtype=dtype),
        "wk": dense_init(ks[1], (d, K * hd), dtype=dtype),
        "wv": dense_init(ks[2], (d, K * hd), dtype=dtype),
        "wo": dense_init(ks[3], (H * hd, d), dtype=dtype),
    }


def _flash(q, k, v, *, causal: bool, chunk: int, q_offset=0):
    """Two-level chunked attention with online softmax.

    q: [B, Sq, K, G, hd]; k, v: [B, Sk, K, hd].  Returns [B, Sq, K, G, hd].
    Scores are computed blockwise in f32; peak live score block is
    [B, K, G, cq, ck] instead of [B, H, Sq, Sk].
    """
    B, Sq, K, G, hd = q.shape
    Sk = k.shape[1]
    Sq_orig, Sk_orig = Sq, Sk
    cq = min(chunk, Sq)
    ck = min(chunk, Sk)
    if Sq % cq:
        pad = cq - Sq % cq
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        Sq += pad
    if Sk % ck:
        pad = ck - Sk % ck
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Sk += pad
    nq, nk = Sq // cq, Sk // ck
    scale = 1.0 / np.sqrt(hd)
    qc = q.reshape(B, nq, cq, K, G, hd).transpose(1, 0, 2, 3, 4, 5)
    kc = k.reshape(B, nk, ck, K, hd)
    vc = v.reshape(B, nk, ck, K, hd)

    def q_body(_, qi_idx):
        qi, iq = qi_idx
        m0 = jnp.full((B, K, G, cq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, K, G, cq), jnp.float32)
        a0 = jnp.zeros((B, cq, K, G, hd), jnp.float32)

        def kv_body(carry, jk):
            m, l, acc = carry
            kj = jax.lax.dynamic_index_in_dim(kc, jk, 1, keepdims=False)
            vj = jax.lax.dynamic_index_in_dim(vc, jk, 1, keepdims=False)
            s = jnp.einsum("bqkgh,bskh->bkgqs", qi, kj,
                           preferred_element_type=jnp.float32) * scale
            kpos = jk * ck + jnp.arange(ck)
            if causal:
                qpos = q_offset + iq * cq + jnp.arange(cq)
                mask = (qpos[:, None] >= kpos[None, :]) & (kpos < Sk_orig)[None]
                s = jnp.where(mask[None, None, None], s, -jnp.inf)
            elif Sk != Sk_orig:
                s = jnp.where((kpos < Sk_orig)[None, None, None, None], s,
                              -jnp.inf)
            blk_max = jnp.max(s, axis=-1)
            m_new = jnp.maximum(m, blk_max)
            # guard fully-masked rows (m_new == -inf)
            m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(jnp.isinf(m_new)[..., None], 0.0, p)
            corr = jnp.exp(jnp.where(jnp.isinf(m), 0.0, m) - m_safe)
            corr = jnp.where(jnp.isinf(m), jnp.where(jnp.isinf(m_new), 1.0, 0.0), corr)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqs,bskh->bqkgh", p, vj,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr.transpose(0, 3, 1, 2)[..., None] + pv
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0), jnp.arange(nk))
        lsafe = jnp.maximum(l, 1e-20)
        out = acc / lsafe.transpose(0, 3, 1, 2)[..., None]
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_body, None, (qc, jnp.arange(nq)))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, K, G, hd)
    return out[:, :Sq_orig]


def attention(
    params: Dict,
    x: jnp.ndarray,                 # [B, S, d]
    positions: jnp.ndarray,         # [B, S]
    cfg: ModelConfig,
    causal: bool = True,
    cache: Optional[Dict] = None,   # {"k": [B, S, K, hd], "v": ..., "pos": int32}
    kv_from: Optional[jnp.ndarray] = None,  # cross-attention source [B, Skv, d]
    cross: bool = False,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """GQA attention.  With ``cache`` and S==1 runs one decode step.

    ``cross=True`` marks cross-attention: no rope, never causal, and the KV
    pair comes from ``kv_from`` (or from a *static* cache {"k","v"} computed
    once from the encoder output).  Returns (output [B, S, d], cache or None).
    """
    B, S, d = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    G = H // K
    q = jnp.einsum("bsd,dn->bsn", x, params["wq"]).reshape(B, S, H, hd)
    if not cross:
        q = apply_rope(q, positions, cfg.rope_theta)
    kv_axis = div_or_none(K, tp())

    if cross and cache is not None and "k" in cache:
        k, v = cache["k"], cache["v"]          # static source cache
    else:
        kv_src = x if kv_from is None else kv_from
        Skv = kv_src.shape[1]
        k = jnp.einsum("bsd,dn->bsn", kv_src, params["wk"]).reshape(B, Skv, K, hd)
        v = jnp.einsum("bsd,dn->bsn", kv_src, params["wv"]).reshape(B, Skv, K, hd)
        if not cross:
            kpos = positions if S == Skv else positions[:, -Skv:]
            k = apply_rope(k, kpos, cfg.rope_theta)

    if not cross and cache is not None and "pos" in cache and S == 1:
        # ---- self-attention decode: append to cache, attend over window -----
        pos = cache["pos"]
        quant = "k_scale" in cache
        if quant:
            k8, ks = kv_quantize(k)
            v8, vs = kv_quantize(v)
            ck = jax.lax.dynamic_update_slice(cache["k"], k8, (0, pos, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], v8, (0, pos, 0, 0))
            cks = jax.lax.dynamic_update_slice(cache["k_scale"], ks,
                                               (0, pos, 0, 0))
            cvs = jax.lax.dynamic_update_slice(cache["v_scale"], vs,
                                               (0, pos, 0, 0))
        else:
            ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, pos, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, pos, 0, 0))
        ck = shard(ck, dp(), tp(), None, None)
        cv = shard(cv, dp(), tp(), None, None)
        qg = q.reshape(B, 1, K, G, hd)
        if quant:
            # fold scales outside the int8 einsums: s = (q·k8)·scale_k,
            # o = (p·scale_v)·v8 — the dequantized cache never materializes.
            s = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32),
                           ck.astype(jnp.float32),
                           preferred_element_type=jnp.float32)
            s = s * cks[..., 0].transpose(0, 2, 1)[:, :, None, None, :]
            s = s / np.sqrt(hd)
        else:
            s = jnp.einsum("bqkgh,bskh->bkgqs", qg, ck,
                           preferred_element_type=jnp.float32) / np.sqrt(hd)
        s = shard(s, dp(), None, None, None, tp())
        span = ck.shape[1]
        valid = jnp.arange(span)[None] <= pos
        s = jnp.where(valid[:, None, None, None], s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        if quant:
            p = p * cvs[..., 0].transpose(0, 2, 1)[:, :, None, None, :]
            o = jnp.einsum("bkgqs,bskh->bqkgh", p, cv.astype(jnp.float32),
                           preferred_element_type=jnp.float32)
        else:
            o = jnp.einsum("bkgqs,bskh->bqkgh", p, cv,
                           preferred_element_type=jnp.float32)
        o = o.astype(x.dtype).reshape(B, 1, H * hd)
        out = jnp.einsum("bsn,nd->bsd", o, params["wo"])
        new_cache = {"k": ck, "v": cv, "pos": pos + 1}
        if quant:
            new_cache.update(k_scale=cks, v_scale=cvs)
        return out, new_cache

    if cross and S == 1:
        # ---- cross-attention decode against the static source cache ---------
        qg = q.reshape(B, 1, K, G, hd)
        s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                       preferred_element_type=jnp.float32) / np.sqrt(hd)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqs,bskh->bqkgh", p, v, preferred_element_type=jnp.float32)
        o = o.astype(x.dtype).reshape(B, 1, H * hd)
        return jnp.einsum("bsn,nd->bsd", o, params["wo"]), cache

    # ---- full attention (train / prefill) ----------------------------------
    qg = q.reshape(B, S, K, G, hd)
    qg = shard(qg, dp(), None, kv_axis, None, None)
    k = shard(k, dp(), None, kv_axis, None)
    v = shard(v, dp(), None, kv_axis, None)
    o = _flash(qg, k, v, causal=causal and not cross, chunk=cfg.attn_chunk)
    o = o.reshape(B, S, H * hd)
    out = row_parallel_matmul(o, params["wo"], cfg)
    out_cache = None
    if cache is not None and not cross:
        if cfg.kv_quant:
            k8, ks = kv_quantize(k)
            v8, vs = kv_quantize(v)
            out_cache = {"k": k8, "v": v8, "k_scale": ks, "v_scale": vs,
                         "pos": jnp.asarray(S, jnp.int32)}
        else:
            out_cache = {"k": k, "v": v, "pos": jnp.asarray(S, jnp.int32)}
    elif cache is not None:
        out_cache = {"k": k, "v": v}
    return out, out_cache


def make_cache(cfg: ModelConfig, batch: int, length: int, dtype=jnp.bfloat16) -> Dict:
    K, hd = cfg.n_kv_heads, cfg.hd
    if cfg.kv_quant:
        return {
            "k": jnp.zeros((batch, length, K, hd), jnp.int8),
            "v": jnp.zeros((batch, length, K, hd), jnp.int8),
            "k_scale": jnp.zeros((batch, length, K, 1), jnp.float32),
            "v_scale": jnp.zeros((batch, length, K, 1), jnp.float32),
            "pos": jnp.asarray(0, jnp.int32),
        }
    return {
        "k": jnp.zeros((batch, length, K, hd), dtype),
        "v": jnp.zeros((batch, length, K, hd), dtype),
        "pos": jnp.asarray(0, jnp.int32),
    }


# =============================================================================
# Dense MLP
# =============================================================================

def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None, dtype=jnp.float32) -> Dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = split_keys(key, 3)
    p = {
        "up": dense_init(ks[0], (d, f), dtype=dtype),
        "down": dense_init(ks[1], (f, d), dtype=dtype),
    }
    if cfg.activation == "swiglu":
        p["gate"] = dense_init(ks[2], (d, f), dtype=dtype)
    return p


def mlp(params: Dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    up = jnp.einsum("bsd,df->bsf", x, params["up"])
    up = shard(up, dp(), None, tp())
    if cfg.activation == "swiglu":
        gate = jnp.einsum("bsd,df->bsf", x, params["gate"])
        h = jax.nn.silu(gate) * up
    else:
        h = ACTIVATIONS[cfg.activation](up)
    out = row_parallel_matmul(h, params["down"], cfg)
    return shard(out, dp(), None, None)


# =============================================================================
# Mixture of Experts
# =============================================================================

def init_moe(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = split_keys(key, 5)
    p = {
        "router": dense_init(ks[0], (d, E), dtype=jnp.float32),
        "up": dense_init(ks[1], (E, d, f), in_axis=1, dtype=dtype),
        "down": dense_init(ks[2], (E, f, d), in_axis=1, dtype=dtype),
    }
    if cfg.activation == "swiglu":
        p["gate"] = dense_init(ks[3], (E, d, f), in_axis=1, dtype=dtype)
    if cfg.n_shared_experts:
        sub = dataclass_replace_dff(cfg, cfg.n_shared_experts * cfg.d_ff)
        p["shared"] = init_mlp(ks[4], sub, dtype=dtype)
    return p


def dataclass_replace_dff(cfg: ModelConfig, f: int) -> ModelConfig:
    import dataclasses
    return dataclasses.replace(cfg, d_ff=f)


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    c = int(np.ceil(tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts))
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


def _route(params, xf, cfg: ModelConfig):
    """Router: returns (gates [T,k], experts [T,k], aux_loss scalar)."""
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eids = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    # Switch-style load-balance loss
    E = cfg.n_experts
    me = jnp.mean(probs, axis=0)                                   # [E]
    ce = jnp.mean(jax.nn.one_hot(eids[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce)
    return gates, eids, aux


def _expert_ffn(params, xg: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """xg: [E, C, d] -> [E, C, d] through each expert's FFN."""
    up = jnp.einsum("ecd,edf->ecf", xg, params["up"])
    if cfg.activation == "swiglu":
        gate = jnp.einsum("ecd,edf->ecf", xg, params["gate"])
        h = jax.nn.silu(gate) * up
    else:
        h = ACTIVATIONS[cfg.activation](up)
    return jnp.einsum("ecf,efd->ecd", h, params["down"])


def moe_dense(params: Dict, x: jnp.ndarray, cfg: ModelConfig):
    """Sort/scatter top-k dispatch under plain pjit (baseline).

    Static shapes throughout; overflow beyond expert capacity is dropped
    (standard capacity-factor semantics).
    """
    B, S, d = x.shape
    T = B * S
    xf = x.reshape(T, d)
    gates, eids, aux = _route(params, xf, cfg)
    k, E = cfg.top_k, cfg.n_experts
    C = _capacity(T, cfg)

    flat_e = eids.reshape(-1)                                # [T*k]
    sidx = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[sidx]
    first_occ = jnp.searchsorted(sorted_e, sorted_e, side="left")
    rank = jnp.arange(T * k) - first_occ
    keep = rank < C
    slot = jnp.where(keep, sorted_e * C + rank, E * C)       # E*C = drop bin
    tok = sidx // k

    xg = jnp.zeros((E * C + 1, d), x.dtype).at[slot].set(xf[tok])
    yg = _expert_ffn(params, xg[:-1].reshape(E, C, d), cfg)
    if not LEGACY_JAX:
        # on old XLA this constraint makes GSPMD miscompile the surrounding
        # sort/scatter dispatch on multi-axis meshes (wrong values, no error);
        # it is only a partitioning hint, so drop it there
        yg = shard(yg, tp(), None, None)
    y_sorted = jnp.concatenate([yg.reshape(E * C, d),
                                jnp.zeros((1, d), yg.dtype)])[slot]
    gsel = gates.reshape(-1)[sidx]
    contrib = y_sorted * gsel[:, None].astype(y_sorted.dtype)
    y = jnp.zeros((T, d), contrib.dtype).at[tok].add(contrib)
    y = y.astype(x.dtype)
    if cfg.n_shared_experts:
        y = y + mlp(params["shared"], x, cfg).reshape(T, d)
    return shard(y.reshape(B, S, d), dp(), None, None), aux


def moe_a2a(params: Dict, x: jnp.ndarray, cfg: ModelConfig, mesh):
    """shard_map expert-parallel dispatch with explicit all_to_all (optimized).

    Activations are *sequence-sharded* over the ``model`` axis on entry
    (GShard-style), so every token is dispatched exactly once — with plain
    dp sharding the token stream is replicated over ``model`` and each TP
    rank would redundantly compute every expert slot.  Only the capacity
    buffers cross the ``model`` axis (2 all_to_alls).  For S == 1 (decode)
    the sequence cannot be sharded; dispatch is then replicated over
    ``model`` (identical results per rank, negligible at one token).
    """
    from repro.compat import shard_map

    tp_axis = tp()
    dp_spec = dp()
    E, kk = cfg.n_experts, cfg.top_k
    tp_sz = mesh.shape[tp_axis] if tp_axis in mesh.axis_names else 1
    seq_shard = x.shape[1] % tp_sz == 0 and x.shape[1] >= tp_sz
    seq_axis = tp_axis if seq_shard else None
    mean_axes = (dp_spec,) if isinstance(dp_spec, str) else tuple(dp_spec)
    if seq_shard:
        mean_axes = mean_axes + (tp_axis,)

    def local_fn(x_loc, router, up, gate, down, shared):
        Bl, Sl, d = x_loc.shape
        Tl = Bl * Sl
        xf = x_loc.reshape(Tl, d)
        p_loc = {"router": router, "up": up, "down": down}
        if gate is not None:
            p_loc["gate"] = gate
        gates, eids, aux = _route(p_loc, xf, cfg)
        aux = jax.lax.pmean(aux, mean_axes)
        C = _capacity(Tl, cfg)
        flat_e = eids.reshape(-1)
        sidx = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[sidx]
        rank = jnp.arange(Tl * kk) - jnp.searchsorted(sorted_e, sorted_e, "left")
        keep = rank < C
        slot = jnp.where(keep, sorted_e * C + rank, E * C)
        tok = sidx // kk
        xg = jnp.zeros((E * C + 1, d), x_loc.dtype).at[slot].set(xf[tok])
        xg = xg[:-1].reshape(E, C, d)
        ep = axis_size(tp_axis)
        # [E, C, d] -a2a-> [E/ep, ep*C, d]: local slots for this shard's experts
        xg = jax.lax.all_to_all(xg, tp_axis, split_axis=0, concat_axis=1,
                                tiled=True)
        p_exp = {"up": up, "down": down}
        if gate is not None:
            p_exp["gate"] = gate
        yg = _expert_ffn(p_exp, xg, cfg)
        # reverse: [E/ep, ep*C, d] -a2a-> [E, C, d]
        yg = jax.lax.all_to_all(yg, tp_axis, split_axis=1, concat_axis=0,
                                tiled=True)
        yg = yg.reshape(E * C, d)
        y_sorted = jnp.concatenate([yg, jnp.zeros((1, d), yg.dtype)])[slot]
        gsel = gates.reshape(-1)[sidx]
        y = jnp.zeros((Tl, d), jnp.float32).at[tok].add(
            y_sorted.astype(jnp.float32) * gsel[:, None])
        return y.astype(x_loc.dtype).reshape(Bl, Sl, d), aux

    gate = params.get("gate")
    in_specs = (
        P(dp_spec, seq_axis, None), P(), P(tp_axis, None, None),
        P(tp_axis, None, None) if gate is not None else P(),
        P(tp_axis, None, None), P(),
    )
    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=in_specs,
        out_specs=(P(dp_spec, seq_axis, None), P()),
    )
    y, aux = fn(x, params["router"], params["up"], gate, params["down"], None)
    y = shard(y, dp(), None, None)   # re-gather the sequence for the next block
    if cfg.n_shared_experts:
        y = y + mlp(params["shared"], x, cfg)
    return y, jnp.mean(aux)


def moe(params: Dict, x: jnp.ndarray, cfg: ModelConfig, mesh=None):
    if cfg.moe_impl == "a2a" and mesh is not None:
        return moe_a2a(params, x, cfg, mesh)
    return moe_dense(params, x, cfg)
