"""Decoder-only LM assembly for all four families: dense, moe, ssm, hybrid.

Layer stacks are ``lax.scan`` over stacked parameters (one layer's HLO,
iterated — keeps compile time and HLO size flat in depth), with per-layer
``jax.checkpoint`` for training.  The hybrid (zamba2) family scans over
*groups* of ``attn_period`` Mamba2 layers followed by one application of a
single *shared* attention+MLP block (parameters closed over, not scanned).

Entry points:
  init(key, cfg)                        -> params pytree
  loss_fn(params, cfg, batch, mesh)     -> (loss, metrics)
  prefill(params, cfg, tokens|embeds)   -> (last-token logits, caches)
  decode_step(params, cfg, token, caches, mesh) -> (logits, caches)
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import dense_init, rms_norm, split_keys
from .config import ModelConfig
from .layers import attention, init_attention, init_mlp, init_moe, make_cache, mlp, moe
from .sharding import dp, shard, tp
from .ssm import init_ssm, make_ssm_cache, ssm_block


# =============================================================================
# init
# =============================================================================

def _init_block(key, cfg: ModelConfig, dtype):
    if cfg.family == "ssm" or cfg.family == "hybrid":
        ks = split_keys(key, 2)
        return {"ln": jnp.ones((cfg.d_model,), dtype),
                "ssm": init_ssm(ks[0], cfg, dtype)}
    ks = split_keys(key, 3)
    p = {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "attn": init_attention(ks[0], cfg, dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
    }
    if cfg.family == "moe":
        p["moe"] = init_moe(ks[1], cfg, dtype)
    else:
        p["mlp"] = init_mlp(ks[1], cfg, dtype=dtype)
    return p


def _init_shared_attn(key, cfg: ModelConfig, dtype):
    """Zamba2's shared attention+MLP block (one copy, applied every period)."""
    ks = split_keys(key, 3)
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "attn": init_attention(ks[0], cfg, dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "mlp": init_mlp(ks[1], cfg, dtype=dtype),
    }


def init(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Dict:
    ks = split_keys(key, 4)
    L = cfg.n_layers
    layer_keys = jax.random.split(ks[0], L)
    layers = jax.vmap(lambda k: _init_block(k, cfg, dtype))(layer_keys)
    params = {
        "embed": dense_init(ks[1], (cfg.vocab, cfg.d_model), in_axis=1, dtype=dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "layers": layers,
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(ks[2], (cfg.d_model, cfg.vocab), dtype=dtype)
    if cfg.family == "hybrid":
        params["shared_attn"] = _init_shared_attn(ks[3], cfg, dtype)
    return params


# =============================================================================
# blocks
# =============================================================================

def _dense_block(p, h, positions, cfg: ModelConfig, mesh=None, cache=None):
    a, new_cache = attention(p["attn"], rms_norm(h, p["ln1"], cfg.norm_eps),
                             positions, cfg, causal=True, cache=cache)
    h = h + a
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "moe":
        m, aux = moe(p["moe"], rms_norm(h, p["ln2"], cfg.norm_eps), cfg, mesh)
    else:
        m = mlp(p["mlp"], rms_norm(h, p["ln2"], cfg.norm_eps), cfg)
    return h + m, aux, new_cache


def _ssm_layer(p, h, cfg: ModelConfig, cache=None):
    s, new_cache = ssm_block(p["ssm"], rms_norm(h, p["ln"], cfg.norm_eps),
                             cfg, cache=cache)
    return h + s, new_cache


def _shared_attn_block(p, h, positions, cfg: ModelConfig, cache=None):
    a, new_cache = attention(p["attn"], rms_norm(h, p["ln1"], cfg.norm_eps),
                             positions, cfg, causal=True, cache=cache)
    h = h + a
    h = h + mlp(p["mlp"], rms_norm(h, p["ln2"], cfg.norm_eps), cfg)
    return h, new_cache


# =============================================================================
# stacks (scan over layers)
# =============================================================================

def _maybe_remat(fn, cfg: ModelConfig, train: bool):
    if train and cfg.remat:
        return jax.checkpoint(fn)
    return fn


def _stack_dense(params, h, positions, cfg, mesh, train):
    def block(layer_p, hh):
        hh, a, _ = _dense_block(layer_p, hh, positions, cfg, mesh)
        return hh, a

    block = _maybe_remat(block, cfg, train)

    def body(hh, layer_p):
        hh, a = block(layer_p, hh)
        return hh, a

    h, auxs = jax.lax.scan(body, h, params["layers"])
    return h, jnp.sum(auxs)


def _stack_ssm(params, h, positions, cfg, mesh, train):
    def body(carry, layer_p):
        hh = _ssm_layer(layer_p, carry, cfg)[0]
        return hh, None

    h, _ = jax.lax.scan(_maybe_remat(body, cfg, train), h, params["layers"])
    return h, jnp.zeros((), jnp.float32)


def _group_params(layers, period: int, n_groups: int, tail: int):
    main = jax.tree.map(lambda x: x[: n_groups * period].reshape(
        (n_groups, period) + x.shape[1:]), layers)
    tail_p = jax.tree.map(lambda x: x[n_groups * period:], layers)
    return main, tail_p


def _stack_hybrid(params, h, positions, cfg, mesh, train):
    period = cfg.attn_period
    L = cfg.n_layers
    n_groups, tail = L // period, L % period
    main, tail_p = _group_params(params["layers"], period, n_groups, tail)
    shared = params["shared_attn"]

    def group_body(carry, group_p):
        hh = carry

        def inner(c, lp):
            return _ssm_layer(lp, c, cfg)[0], None

        hh, _ = jax.lax.scan(inner, hh, group_p)
        hh, _ = _shared_attn_block(shared, hh, positions, cfg)
        return hh, None

    h, _ = jax.lax.scan(_maybe_remat(group_body, cfg, train), h, main)
    if tail:
        def inner_t(c, lp):
            return _ssm_layer(lp, c, cfg)[0], None

        h, _ = jax.lax.scan(_maybe_remat(inner_t, cfg, train), h, tail_p)
    return h, jnp.zeros((), jnp.float32)


_STACKS = {"dense": _stack_dense, "moe": _stack_dense,
           "ssm": _stack_ssm, "hybrid": _stack_hybrid}


# =============================================================================
# forward / loss
# =============================================================================

def embed_tokens(params, tokens):
    e = jnp.take(params["embed"], tokens, axis=0)
    return shard(e, dp(), None, None)


def forward(params, cfg: ModelConfig, tokens=None, embeds=None, mesh=None,
            train: bool = False):
    h = embed_tokens(params, tokens) if embeds is None else embeds
    B, S = h.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    h = shard(h, dp(), None, None)
    h, aux = _STACKS[cfg.family](params, h, positions, cfg, mesh, train)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    return h, aux


def unembed_matrix(params):
    if "unembed" in params:
        return params["unembed"]                       # [d, V]
    return params["embed"].T                           # tied


def lm_loss_from_h(params, cfg: ModelConfig, h, labels):
    """Cross entropy with vocab-sharded logits.

    logsumexp reduces over the sharded vocab dim (SPMD all-reduce over tp);
    the label logit is recovered by gathering unembedding *rows* — avoids a
    gather on the [B,S,V] tensor."""
    W = unembed_matrix(params)                         # [d, V]
    logits = jnp.einsum("bsd,dv->bsv", h, W, preferred_element_type=jnp.float32)
    logits = shard(logits, dp(), None, tp())
    lse = jax.nn.logsumexp(logits, axis=-1)            # [B, S]
    rows = jnp.take(W.T, labels, axis=0)               # [B, S, d]
    label_logit = jnp.einsum("bsd,bsd->bs", h.astype(jnp.float32),
                             rows.astype(jnp.float32))
    return jnp.mean(lse - label_logit)


def loss_fn(params, cfg: ModelConfig, batch: Dict, mesh=None):
    """batch: {"tokens": [B,S]} or {"embeds": [B,S,d]}, with {"labels": [B,S]}."""
    h, aux = forward(params, cfg,
                     tokens=batch.get("tokens"), embeds=batch.get("embeds"),
                     mesh=mesh, train=True)
    ce = lm_loss_from_h(params, cfg, h, batch["labels"])
    loss = ce + 0.01 * aux
    return loss, {"ce": ce, "aux": aux}


# =============================================================================
# serving: prefill + decode
# =============================================================================

def make_caches(cfg: ModelConfig, batch: int, length: int, dtype=jnp.bfloat16):
    L = cfg.n_layers
    if cfg.family in ("dense", "moe"):
        one = make_cache(cfg, batch, length, dtype)
        return {"attn": jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (L,) + x.shape).copy(), one)}
    if cfg.family == "ssm":
        one = make_ssm_cache(cfg, batch, dtype)
        return {"ssm": jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (L,) + x.shape).copy(), one)}
    # hybrid
    period = cfg.attn_period
    n_groups, tail = cfg.n_layers // period, cfg.n_layers % period
    ssm_one = make_ssm_cache(cfg, batch, dtype)
    attn_one = make_cache(cfg, batch, length, dtype)
    return {
        "ssm_main": jax.tree.map(
            lambda x: jnp.broadcast_to(x[None, None],
                                       (n_groups, period) + x.shape).copy(), ssm_one),
        "ssm_tail": jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (tail,) + x.shape).copy(), ssm_one),
        "attn": jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n_groups,) + x.shape).copy(),
            attn_one),
    }


def grow_caches(cfg: ModelConfig, caches, window: int):
    """Pad attention KV windows (from prefill) up to ``window`` for decoding."""
    def pad_kv(c):
        cur = c["k"].shape[2]  # [L, B, S, K, hd]
        if cur >= window:
            return c
        pad = window - cur
        out = {
            key: (jnp.pad(val, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
                  if key != "pos" else val)
            for key, val in c.items()
        }
        return out

    out = dict(caches)
    if "attn" in caches and caches["attn"] is not None:
        out["attn"] = pad_kv(caches["attn"])
    return out


def decode_step(params, cfg: ModelConfig, tokens, caches, mesh=None,
                embeds=None):
    """One token for every sequence in the batch.  tokens: [B, 1]."""
    h = embed_tokens(params, tokens) if embeds is None else embeds
    B = h.shape[0]

    if cfg.family in ("dense", "moe"):
        pos0 = caches["attn"]["pos"][0]
        positions = jnp.broadcast_to(pos0[None, None], (B, 1))

        def body(carry, xs):
            hh = carry
            layer_p, cache_l = xs
            hh, aux, new_c = _dense_block(layer_p, hh, positions, cfg, mesh,
                                          cache=cache_l)
            return hh, new_c

        h, new_attn = jax.lax.scan(body, h, (params["layers"], caches["attn"]))
        new_caches = {"attn": new_attn}
    elif cfg.family == "ssm":
        def body(carry, xs):
            layer_p, cache_l = xs
            hh, new_c = _ssm_layer(layer_p, carry, cfg, cache=cache_l)
            return hh, new_c

        h, new_ssm = jax.lax.scan(body, h, (params["layers"], caches["ssm"]))
        new_caches = {"ssm": new_ssm}
    else:  # hybrid
        period = cfg.attn_period
        n_groups, tail = cfg.n_layers // period, cfg.n_layers % period
        main, tail_p = _group_params(params["layers"], period, n_groups, tail)
        shared = params["shared_attn"]
        pos0 = caches["attn"]["pos"][0]
        positions = jnp.broadcast_to(pos0[None, None], (B, 1))

        def group_body(carry, xs):
            hh = carry
            group_p, ssm_c, attn_c = xs

            def inner(c, lp_and_cache):
                lp, sc = lp_and_cache
                h2, nsc = _ssm_layer(lp, c, cfg, cache=sc)
                return h2, nsc

            hh, new_ssm_c = jax.lax.scan(inner, hh, (group_p, ssm_c))
            hh, new_attn_c = _shared_attn_block(shared, hh, positions, cfg,
                                                cache=attn_c)
            return hh, (new_ssm_c, new_attn_c)

        h, (new_ssm_main, new_attn) = jax.lax.scan(
            group_body, h, (main, caches["ssm_main"], caches["attn"]))
        new_ssm_tail = caches["ssm_tail"]
        if tail:
            def inner_t(c, xs):
                lp, sc = xs
                h2, nsc = _ssm_layer(lp, c, cfg, cache=sc)
                return h2, nsc

            h, new_ssm_tail = jax.lax.scan(inner_t, h,
                                           (tail_p, caches["ssm_tail"]))
        new_caches = {"ssm_main": new_ssm_main, "ssm_tail": new_ssm_tail,
                      "attn": new_attn}

    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h, unembed_matrix(params),
                        preferred_element_type=jnp.float32)
    logits = shard(logits, dp(), None, tp())
    return logits[:, 0], new_caches


def prefill(params, cfg: ModelConfig, tokens=None, embeds=None, mesh=None):
    """Process the prompt; returns (last-position logits, caches primed at S).

    Uses the full-sequence path per layer and records caches.  For attention
    families the cache window equals the prompt length (decode then grows it —
    the dry-run decode shape allocates the full window instead)."""
    h = embed_tokens(params, tokens) if embeds is None else embeds
    B, S = h.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    if cfg.family in ("dense", "moe"):
        def body(carry, layer_p):
            hh = carry
            hh, aux, cache = _dense_block(layer_p, hh, positions, cfg, mesh,
                                          cache={})
            return hh, cache

        h, caches = jax.lax.scan(body, h, params["layers"])
        new_caches = {"attn": caches}
    elif cfg.family == "ssm":
        def body(carry, layer_p):
            hh, c = _ssm_layer(layer_p, carry, cfg,
                               cache=make_ssm_cache(cfg, B, h.dtype))
            return hh, c

        h, caches = jax.lax.scan(body, h, params["layers"])
        new_caches = {"ssm": caches}
    else:
        period = cfg.attn_period
        n_groups, tail = cfg.n_layers // period, cfg.n_layers % period
        main, tail_p = _group_params(params["layers"], period, n_groups, tail)
        shared = params["shared_attn"]

        def group_body(carry, group_p):
            hh = carry

            def inner(c, lp):
                h2, sc = _ssm_layer(lp, c, cfg, cache=make_ssm_cache(cfg, B, h.dtype))
                return h2, sc

            hh, ssm_c = jax.lax.scan(inner, hh, group_p)
            hh, attn_c = _shared_attn_block(shared, hh, positions, cfg, cache={})
            return hh, (ssm_c, attn_c)

        h, (ssm_main, attn_c) = jax.lax.scan(group_body, h, main)
        ssm_tail = None
        if tail:
            def inner_t(c, lp):
                h2, sc = _ssm_layer(lp, c, cfg, cache=make_ssm_cache(cfg, B, h.dtype))
                return h2, sc

            h, ssm_tail = jax.lax.scan(inner_t, h, tail_p)
        new_caches = {"ssm_main": ssm_main, "ssm_tail": ssm_tail, "attn": attn_c}

    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", h[:, -1], unembed_matrix(params),
                        preferred_element_type=jnp.float32)
    return logits, new_caches
