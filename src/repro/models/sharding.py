"""Logical-axis sharding helpers.

Models annotate activations with *logical* axes (dp = batch, tp = tensor/model
parallel) and parameters with PartitionSpecs built from the same vocabulary.
The mapping adapts to the active mesh: on the multi-pod mesh the batch axis
spans ("pod", "data"); on the single-pod mesh just "data"; on a test mesh
whatever is registered.  ``set_mesh_axes`` is called by the launcher (and by
tests) before tracing.
"""

from __future__ import annotations

import threading
from typing import Optional, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

from repro.compat import get_abstract_mesh

_state = threading.local()


def set_mesh_axes(dp: Tuple[str, ...] = ("data",), tp: Optional[str] = "model"):
    _state.dp = tuple(dp)
    _state.tp = tp


def axes_from_mesh(mesh) -> None:
    names = mesh.axis_names
    dp = tuple(n for n in names if n in ("pod", "data", "replica"))
    tp = "model" if "model" in names else None
    set_mesh_axes(dp or ("data",), tp)


def dp() -> Union[Tuple[str, ...], str, None]:
    d = getattr(_state, "dp", ("data",))
    if len(d) == 1:
        return d[0]
    return d


def tp() -> Optional[str]:
    return getattr(_state, "tp", "model")


def shard(x, *spec):
    """with_sharding_constraint, tolerant of running without a mesh (tests)."""
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except (ValueError, RuntimeError):
        return x


def tp_size(mesh=None) -> int:
    m = mesh or _current_mesh()
    if m is None or tp() is None:
        return 1
    try:
        return m.shape[tp()]
    except (KeyError, TypeError):
        return 1


def _current_mesh():
    m = get_abstract_mesh()
    if m is not None and not m.empty:
        return m
    return None


def div_or_none(n: int, axis_name: Optional[str], mesh=None):
    """Return axis_name if it divides n on the active mesh, else None.

    Used for dims that are only sometimes shardable (e.g. kv heads < tp)."""
    if axis_name is None:
        return None
    m = mesh or _current_mesh()
    if m is None:
        return axis_name
    try:
        size = m.shape[axis_name]
    except (KeyError, TypeError):
        return None
    return axis_name if n % size == 0 and n >= size else None
