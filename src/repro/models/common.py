"""Shared model components: norms, rope, activations, init helpers."""

from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """RMSNorm with f32 statistics.

    The sum-of-squares is accumulated in f32 via einsum rather than
    materializing convert(x) — with layer-stacked scans XLA otherwise keeps a
    whole-stack f32 *copy* of the saved bf16 activations alive for the
    backward pass (observed: +8 GiB/dev on a 32L model; see EXPERIMENTS.md
    §Perf memory iteration 1)."""
    dt = x.dtype
    d = x.shape[-1]
    ss = jnp.einsum("...d,...d->...", x, x, preferred_element_type=jnp.float32)
    inv = jax.lax.rsqrt(ss / d + eps)[..., None]
    return (x * (inv * scale.astype(jnp.float32)).astype(dt)).astype(dt)


def squared_relu(x):
    r = jax.nn.relu(x)
    return r * r


ACTIVATIONS: Dict[str, Callable] = {
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
    "silu": jax.nn.silu,
    "squared_relu": squared_relu,
}


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., S, H, hd]; positions: [..., S] (broadcastable)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # [hd/2]
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # [..., S, 1, hd/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32) -> jnp.ndarray:
    fan_in = shape[in_axis]
    return (jax.random.normal(key, shape) / np.sqrt(fan_in)).astype(dtype)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))
